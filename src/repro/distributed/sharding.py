"""Divisibility-aware named-sharding rules (DP/FSDP/TP/EP/SP).

Every logical tensor dim carries an ordered list of candidate mesh axes
(single names or tuples for composite axes); ``greedy_spec`` assigns the
first candidate whose axis product divides the dim and whose axes are still
unused for this tensor, else leaves the dim replicated. This is what lets
one rule set cover all 10 assigned architectures: 28 heads or 40 experts
simply fall through to the next candidate instead of producing an invalid
sharding (DESIGN.md §5).

Param rules are path-based: the pytree path (e.g. ``blocks/attn/wq/w``)
selects a rule; stacked layer dims (leading ``L``) are auto-detected and
skipped. FSDP ("zero-3") sharding rides the ``data`` axis on the non-TP dim
of every large matrix, which also fully shards the (same-shaped) AdamW
moments.
"""
from __future__ import annotations

import math
import re
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Candidates = Sequence  # each entry: axis name, tuple of names, or None


def _axes_of(cand):
    return cand if isinstance(cand, tuple) else (cand,)


def greedy_spec(shape, dim_prefs, mesh: Mesh, priority=None) -> P:
    """Assign the first still-unused, divisible candidate axis per dim.
    ``priority`` reorders which dims claim axes first (default: dim order)."""
    used = set()
    spec = [None] * len(shape)
    order = priority if priority is not None else range(len(shape))
    for i in order:
        size, prefs = shape[i], (dim_prefs[i] if i < len(dim_prefs) else ())
        for cand in prefs or ():
            if cand is None:
                break
            axes = _axes_of(cand)
            if any(a in used or a not in mesh.shape for a in axes):
                continue
            prod = math.prod(mesh.shape[a] for a in axes)
            if prod > 1 and size % prod == 0:
                spec[i] = cand
                used.update(axes)
                break
    while spec and spec[-1] is None:
        spec.pop()
    return P(*spec)


# ---------------------------------------------------------------------------
# Parameter rules
# ---------------------------------------------------------------------------
FSDP = ("data",)          # candidates for the "shard-for-memory" dim
TP = ("model",)           # candidates for the "shard-for-compute" dim
EP = ("model",)           # expert-parallel axis

# (path regex, dim_prefs for the *unstacked* shape)
_PARAM_RULES = [
    # embeddings / unembeddings: (vocab, d)
    (r"embed/table$", [TP, FSDP]),
    (r"lm_head/w$", [FSDP, TP]),
    (r"(frame|patch)_proj/w$", [None, TP]),
    # attention projections: (d, features) / (features, d)
    (r"attn/w[qkv]/w$", [FSDP, TP]),
    (r"attn/w[qkv]/b$", [TP]),
    (r"attn/wo/w$", [TP, FSDP]),
    # MLA
    (r"attn/wkv_a/w$", [FSDP, TP]),
    (r"attn/wkv_b/w$", [FSDP, TP]),
    # MLPs: (d, ff) up / (ff, d) down
    (r"mlp/(gate|up)/w$", [FSDP, TP]),
    (r"mlp/down/w$", [TP, FSDP]),
    # MoE: router (d, E); experts (E, d, f) / (E, f, d)
    (r"moe/router/w$", [FSDP, None]),
    (r"moe/(gate|up)$", [EP, FSDP, TP]),
    (r"moe/down$", [EP, TP, FSDP]),
    (r"moe/shared/(gate|up)/w$", [FSDP, TP]),
    (r"moe/shared/down/w$", [TP, FSDP]),
    # mamba2
    (r"mamba/in_proj/w$", [FSDP, TP]),
    (r"mamba/out_proj/w$", [TP, FSDP]),
    (r"mamba/conv_w$", [None, TP]),
    (r"mamba/conv_b$", [TP]),
    # xlstm cells
    (r"cell/w[qkvif]/w$", [FSDP, TP]),
    (r"cell/(wo_gate|out_proj)/w$", [TP, FSDP]),
    (r"cell/w_in/w$", [FSDP, TP]),
    # generic biases / norms / small vectors: replicate
    (r"(ln\d?|norm|final_norm|kv_norm)/", []),
]

_STACKED_PREFIXES = ("blocks/", "mamba/")  # leading layer dim present


def _path_str(path) -> str:
    parts = []
    for p in path:
        parts.append(str(getattr(p, "key", getattr(p, "idx", p))))
    return "/".join(parts)


def strip_axis(spec: P, axis: str) -> P:
    """Remove one mesh axis from a spec (e.g. drop FSDP for serving)."""
    out = []
    for entry in spec:
        if entry == axis:
            out.append(None)
        elif isinstance(entry, tuple):
            kept = tuple(a for a in entry if a != axis)
            out.append(kept if len(kept) > 1 else (kept[0] if kept else None))
        else:
            out.append(entry)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def param_spec(path: str, shape, mesh: Mesh, fsdp: bool = True) -> P:
    """``fsdp=False`` drops the ``data``-axis (ZeRO) sharding — the serving
    profile: weights live TP-sharded and are never re-gathered per step."""
    lead = 1 if path.startswith(_STACKED_PREFIXES) else 0
    core_shape = shape[lead:]
    spec = None
    for pat, prefs in _PARAM_RULES:
        if re.search(pat, path):
            spec = greedy_spec(core_shape, prefs, mesh)
            break
    if spec is None:
        # generic fallback: biggest dim -> model, next -> data (if divisible)
        if len(core_shape) >= 2 and math.prod(core_shape) >= 1 << 16:
            order = sorted(range(len(core_shape)), key=lambda i: -core_shape[i])
            prefs = [[] for _ in core_shape]
            prefs[order[0]] = TP
            if len(order) > 1:
                prefs[order[1]] = FSDP
            spec = greedy_spec(core_shape, prefs, mesh)
        else:
            spec = P()
    if not fsdp:
        spec = strip_axis(spec, "data")
    return P(*([None] * lead + list(spec)))


def param_shardings(params_tree, mesh: Mesh, fsdp: bool = True):
    """Map a pytree of arrays/SDS to NamedShardings via the rules."""
    def one(path, leaf):
        spec = param_spec(_path_str(path), leaf.shape, mesh, fsdp=fsdp)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params_tree)


# ---------------------------------------------------------------------------
# Input / activation / cache rules
# ---------------------------------------------------------------------------
BATCH = (("pod", "data"), "data", "pod")   # composite first, then singles


def batch_spec(shape, mesh: Mesh, seq_axis: Optional[int] = None) -> P:
    """Shard dim0 over batch candidates; optionally dim ``seq_axis`` over the
    model axis (sequence parallelism) when batch can't fill the mesh."""
    prefs = [list(BATCH)] + [[] for _ in shape[1:]]
    if seq_axis is not None:
        prefs[seq_axis] = ["model"]
    return greedy_spec(shape, prefs, mesh)


def input_shardings(batch_tree, mesh: Mesh):
    def one(leaf):
        return NamedSharding(mesh, batch_spec(leaf.shape, mesh))

    return jax.tree.map(one, batch_tree)


def cache_shardings(cache_tree, mesh: Mesh, stacked: bool = True):
    """KV/state cache rules. Leaf layouts (possibly with leading layer dim):
    GQA (B, S, H, D) — batch over (pod,data); heads over model, else seq.
    MLA (B, S, r)    — batch; r over model, else seq.
    SSM (B, H, P, N) / (B, H, P) / conv (B, K, C) — batch; heads/C over model.
    """
    def one(path, leaf):
        shape = leaf.shape
        path_s = _path_str(path)
        if path_s.endswith("offset") or leaf.ndim == 0:
            return NamedSharding(mesh, P())
        lead = 0
        core = list(shape)
        # detect stacked leading layer dim: heuristic — first dim that is the
        # layer count comes before batch; caches built by *_cache_spec put
        # layers first for stacked trees.
        if stacked and ("layers/" in path_s or path_s.startswith("mamba")
                        or path_s.startswith("attn")):
            lead = 1
            core = list(shape[1:])
        prefs = [[] for _ in core]
        prefs[0] = list(BATCH)
        priority = None
        if len(core) == 4:      # (B, S, H, D) or (B, H, P, N)
            if "mamba" in path_s or path_s.endswith(("C", "h")):
                prefs[1] = ["model"]            # heads
            else:
                prefs[2] = ["model"]            # kv heads first ...
                prefs[1] = ["model"]            # ... else sequence
                priority = [0, 2, 1, 3]
        elif len(core) == 3:    # (B, S, r) or (B, K, C) or (B, H, P)
            prefs[2] = ["model"]
            prefs[1] = ["model"]
            priority = [0, 2, 1]
        elif len(core) == 2:
            prefs[1] = ["model"]
        spec = greedy_spec(core, prefs, mesh, priority)
        return NamedSharding(mesh, P(*([None] * lead + list(spec))))

    return jax.tree_util.tree_map_with_path(one, cache_tree)


# ---------------------------------------------------------------------------
# Fleet (FCPO agent-axis) rules
# ---------------------------------------------------------------------------
# Agent-stacked leaves (A, ...): the agent axis is the fleet's data
# parallelism — spread over (pod, data) when A fills both, else data alone.
AGENT = (("pod", "data"), "data")
# Per-pod base networks (P, ...): the FL hierarchy. Pods ride the mesh's
# ``pod`` axis when present (multi-pod production mesh); on a 2D mesh the
# ``data`` candidate only engages when P divides the data axis size —
# otherwise the (small) base networks replicate, which is always valid.
POD = ("pod", "data")


def agent_spec(shape, mesh) -> P:
    """Shard an agent-stacked leaf's leading dim over the agent candidates;
    trailing (per-agent) dims are tiny and stay replicated."""
    if not shape:
        return P()
    return greedy_spec(shape, [list(AGENT)], mesh)


def pod_spec(shape, mesh) -> P:
    """Shard a per-pod leaf's leading dim over the FL-hierarchy candidates."""
    if not shape:
        return P()
    return greedy_spec(shape, [list(POD)], mesh)


def agent_batch_spec(shape, mesh, agent_axis: int = 1) -> P:
    """Episode-major driver inputs, e.g. rates (n_eps, A, n_steps): shard the
    *agent* dim over the agent candidates, replicate the scan/time dims."""
    prefs = [[] for _ in shape]
    if agent_axis < len(shape):
        prefs[agent_axis] = list(AGENT)
    return greedy_spec(shape, prefs, mesh)


def ambient_mesh():
    """The mesh in context at trace time: abstract (jax.set_mesh) or the
    legacy physical resource env (``with mesh:``). None when absent."""
    try:
        m = jax.sharding.get_abstract_mesh()
        if m.shape:
            return m
    except Exception:  # noqa: BLE001
        pass
    try:
        from jax._src import mesh as mesh_lib
        pm = mesh_lib.thread_resources.env.physical_mesh
        if pm is not None and not pm.empty:
            return pm
    except Exception:  # noqa: BLE001
        pass
    return None


def shard_hint(x, *dim_prefs, priority=None):
    """Divisibility-aware ``with_sharding_constraint`` against the AMBIENT
    mesh; a silent no-op when no mesh is in context (tests, single device).

    ``dim_prefs``: per-dim candidate lists as in ``greedy_spec`` (trailing
    dims may be omitted -> replicated).
    """
    mesh = ambient_mesh()
    if mesh is None:
        return x
    prefs = list(dim_prefs) + [[]] * (x.ndim - len(dim_prefs))
    spec = greedy_spec(x.shape, prefs, mesh, priority)
    return jax.lax.with_sharding_constraint(x, spec)


def agent_hint(x):
    """Constrain an agent-stacked intermediate (A, ...) to the fleet's agent
    placement inside jit. With these hints on both sides of the Alg. 1
    segment-sums, XLA's SPMD partitioner lowers the pod aggregation to a
    reduce-scatter + gather over the mesh instead of a full-replica
    reshape. No-op without an ambient mesh."""
    return shard_hint(x, list(AGENT))


def pod_hint(x):
    """Constrain a per-pod intermediate (P, ...) to the FL-hierarchy
    placement inside jit (see ``agent_hint``). No-op without a mesh."""
    return shard_hint(x, list(POD))


def logits_shardings(mesh: Mesh):
    return NamedSharding(mesh, greedy_spec(
        (1 << 30, 1, 1 << 30), [list(BATCH), [], ["model"]], mesh))


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
