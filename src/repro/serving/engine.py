"""Inference serving steps + a bucketed host-side engine.

``make_prefill_step`` / ``make_serve_step`` build the jit-able pure functions
the dry-run lowers and the engine executes. The engine compiles one
executable per (batch-bucket, seq-bucket) — the TPU analogue of the paper's
per-configuration TensorRT engines — and FCPO's iAgent actions select which
bucket runs each step (batch size ↔ BS action, seq/patch bucket ↔ RES action).
"""
from __future__ import annotations

import time
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.registry import Model


def make_prefill_step(model: Model, with_cache: bool = True,
                      use_pallas: bool = False) -> Callable:
    """(params, cache|None, batch) -> (last_logits, cache)."""

    def prefill_step(params, cache, batch):
        logits, new_cache, _ = model.apply(params, batch, cache,
                                           use_pallas=use_pallas)
        return logits[:, -1], new_cache

    if not with_cache:
        def prefill_only(params, batch):
            logits, _, _ = model.apply(params, batch, use_pallas=use_pallas)
            return logits

        return prefill_only
    return prefill_step


def make_serve_step(model: Model, use_pallas: bool = False,
                    greedy: bool = True) -> Callable:
    """One decode step: (params, cache, batch) -> (next_tokens, cache).

    ``batch["tokens"]`` is (B, 1) — the previously emitted token; the step
    appends it to the cache and returns the argmax next token. This is the
    function lowered for the ``decode_32k`` / ``long_500k`` dry-run cells.
    """

    def serve_step(params, cache, batch):
        logits, new_cache, _ = model.apply(params, batch, cache,
                                           use_pallas=use_pallas)
        if greedy:
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            return nxt[:, None], new_cache
        return logits[:, -1], new_cache

    return serve_step


def make_encode_step(model: Model, use_pallas: bool = False) -> Callable:
    """Encoder scoring step (hubert): (params, batch) -> logits."""

    def encode_step(params, batch):
        logits, _, _ = model.apply(params, batch, use_pallas=use_pallas)
        return logits

    return encode_step


# ---------------------------------------------------------------------------
# Host-side bucketed engine
# ---------------------------------------------------------------------------
def _bucket(n: int, buckets) -> int:
    for b in buckets:
        if n <= b:
            return b
    # Falling through to buckets[-1] would make the pad amount negative and
    # crash deep inside jnp.pad with an opaque error; fail loudly instead.
    raise ValueError(
        f"size {n} exceeds the largest compiled bucket {buckets[-1]} "
        f"(buckets={tuple(buckets)}); extend the bucket set or split the "
        f"request into bucket-sized chunks")


class ServingEngine:
    """Bucketed compile-cache serving engine for one model replica.

    FCPO control surface:
      * ``batch_bucket``  — the iAgent BS action picks the compiled batch size
      * ``seq_bucket``    — the RES action picks the input length bucket
        (frame-packing analogue: short requests are packed/padded into it)
      * concurrency is managed by the caller (MT action = in-flight steps)
    """

    def __init__(self, model: Model, params, max_cache_len: int = 4096,
                 batch_buckets=(1, 2, 4, 8, 16, 32, 64),
                 seq_buckets=(128, 256, 512, 1024), cache_dtype=None):
        self.model = model
        self.params = params
        self.max_cache_len = max_cache_len
        self.cache_dtype = cache_dtype or jnp.bfloat16
        self.batch_buckets = tuple(batch_buckets)
        self.seq_buckets = tuple(seq_buckets)
        self._prefill = jax.jit(make_prefill_step(model))
        self._decode = jax.jit(make_serve_step(model))
        self._encode = jax.jit(make_encode_step(model))
        self._caches: Dict[int, Any] = {}
        self.stats = {"prefill_calls": 0, "decode_calls": 0,
                      "padded_tokens": 0, "real_tokens": 0}

    def new_cache(self, batch: int):
        spec = self.model.cache_spec(batch, self.max_cache_len, self.cache_dtype)
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)

    def prefill(self, tokens, extra: Optional[Dict[str, Any]] = None):
        """tokens: (B, S) int array. Pads B and S to buckets; returns
        (last_logits, cache, info)."""
        b, s = tokens.shape
        bb = _bucket(b, self.batch_buckets)
        sb = _bucket(s, self.seq_buckets)
        pad_b, pad_s = bb - b, sb - s
        tok = jnp.pad(tokens, ((0, pad_b), (0, pad_s)))
        batch = {"tokens": tok}
        if extra:
            batch.update(extra)
        cache = self.new_cache(bb)
        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, cache, batch)
        logits.block_until_ready()
        dt = time.perf_counter() - t0
        self.stats["prefill_calls"] += 1
        self.stats["padded_tokens"] += pad_b * sb + b * pad_s
        self.stats["real_tokens"] += b * s
        return logits[:b], cache, {"bucket": (bb, sb), "latency_s": dt}

    def decode(self, cache, last_tokens):
        t0 = time.perf_counter()
        nxt, cache = self._decode(self.params, cache, {"tokens": last_tokens})
        nxt.block_until_ready()
        self.stats["decode_calls"] += 1
        return nxt, cache, {"latency_s": time.perf_counter() - t0}

    def generate(self, tokens, steps: int):
        b = tokens.shape[0]
        bb = _bucket(b, self.batch_buckets)
        tokens = jnp.pad(tokens, ((0, bb - b), (0, 0)))  # decode at bucket size
        logits, cache, _ = self.prefill(tokens)
        cur = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        out = [cur]
        for _ in range(steps - 1):
            cur, cache, _ = self.decode(cache, cur)
            out.append(cur)
        return jnp.concatenate(out, axis=1)[:b]
