"""Request queue + SLO (deadline) accounting for the serving data plane.

Mirrors the paper's metrics: *throughput* (results/s), *effective throughput*
(results that met their end-to-end SLO), queue drops from bounded queues, and
per-request end-to-end latency. Used by the real-engine examples; the
pure-JAX MDP in ``core/env.py`` models the same quantities tensorially.

These classes are also the REFERENCE data plane for the tensorized
request-level twin (``repro.sim``): ``repro.sim.oracle`` drives them
tick-for-tick against ``kernels.ref.sim_microtick`` and the two must agree
request-for-request (tests/test_sim.py; benchmarks/fig_sim_fidelity.py
times the same pair).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, List, Optional, Tuple


@dataclass
class Request:
    rid: int
    arrival_t: float
    size: int = 1           # objects in the frame (paper: objects analyzed)
    done_t: Optional[float] = None

    def latency(self) -> Optional[float]:
        return None if self.done_t is None else self.done_t - self.arrival_t


@dataclass
class BoundedQueue:
    """Bounded FIFO; arrivals beyond capacity are dropped (paper: queue drops,
    part of the iAgent state vector)."""
    capacity: int = 64
    q: Deque[Request] = field(default_factory=deque)
    drops: int = 0

    def push(self, r: Request) -> bool:
        if len(self.q) >= self.capacity:
            self.drops += 1
            return False
        self.q.append(r)
        return True

    def pop_batch(self, n: int) -> List[Request]:
        out = []
        while self.q and len(out) < n:
            out.append(self.q.popleft())
        return out

    def __len__(self):
        return len(self.q)


@dataclass
class SLOTracker:
    slo_s: float = 0.25  # paper: 250 ms end-to-end
    completed: List[Tuple[float, float, int]] = field(default_factory=list)
    # (done_t, latency, size)

    def complete(self, reqs: List[Request], now: float):
        for r in reqs:
            r.done_t = now
            self.completed.append((now, r.latency(), r.size))

    def window(self, now: float, horizon: float = 1.0):
        """(throughput, effective_throughput, mean_latency) over the last
        ``horizon`` seconds."""
        recent = [(t, l, s) for (t, l, s) in self.completed if now - t <= horizon]
        if not recent:
            return 0.0, 0.0, 0.0
        thr = sum(s for _, _, s in recent) / horizon
        eff = sum(s for _, l, s in recent if l <= self.slo_s) / horizon
        lat = sum(l for _, l, _ in recent) / len(recent)
        return thr, eff, lat
